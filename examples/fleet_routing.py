"""Fleet routing walkthrough — the JSQ-vs-affinity trade-off, end to end.

Four replicas of the REAL scheduler (each on its own virtual clock, each
with its own cold compile cache) serve one bursty Zipf-weighted tenant
stream under every routing policy — all described by ONE ``SystemSpec``
with the router swapped per cell, and finished with the committed
heterogeneous/elastic spec (``examples/specs/hetero_fleet.json``). No
device work, deterministic per seed, seconds on CPU.

The point this example makes: load balancing and cache affinity pull in
opposite directions. `jsq` equalizes queues but sprays every tenant's
shapes across all four compile caches; `affinity` pins tenants (few
compiles, warm caches) but lets hot tenants pile up on their pinned
replica; `least_cost` prices both effects — backlog seconds AND the
compile a cold replica would pay — and typically wins tail latency while
merging more aggressively (watch its routing imbalance: concentration is
deliberate, not drift).

Equivalent CLI:

    PYTHONPATH=src python -m repro sweep --spec examples/specs/hetero_fleet.json \
        --axis router.policy=round_robin,jsq,least_cost,affinity

    PYTHONPATH=src python examples/fleet_routing.py
"""

import os

from repro.api import FleetRun, SchedulerSpec, SystemSpec, WorkloadSpec
from repro.sim import ROUTERS

EVENTS = 20_000
REPLICAS = 4
SEED = 0

HETERO_SPEC = os.path.join(os.path.dirname(__file__), "specs",
                           "hetero_fleet.json")


def main() -> None:
    # Zipf arrival shares (mix="fleet"): a few hot tenants dominate
    base = SystemSpec(
        workload=WorkloadSpec(mix="fleet", tenants=12, process="mmpp",
                              events=EVENTS, seed=SEED, rho=0.85),
        scheduler=SchedulerSpec(batching_window_s=0.0005,
                                max_superkernel_size=32),
    )
    base = base.replace(**{"fleet.replicas": REPLICAS,
                           "cost_model.compile_us": 200.0})

    print(f"=== {REPLICAS} replicas, bursty MMPP @ rho=0.85, "
          f"{EVENTS} events, compile cold-start 200us ===")
    print(f"{'router':12s} {'p95 ms':>8s} {'attain':>7s} {'goodput':>10s} "
          f"{'imbal':>6s} {'util':>6s} {'cold%':>6s} {'cold 1st->2nd half':>19s}")
    for router in ROUTERS:
        m = FleetRun(base.replace(**{"router.policy": router})).run_metrics()
        s = m.summary()
        first, second = m.cold_fraction_halves()
        print(f"{router:12s} {s['p95_s']*1e3:8.3f} {s['slo_attainment']:7.3f} "
              f"{s['goodput_cost_per_s']:10.4g} {s['routing_imbalance']:6.3f} "
              f"{s['utilization']:6.3f} {s['cold_start_fraction']*100:6.2f} "
              f"{first:9.3f} -> {second:.3f}")

    print("\nround_robin balances counts but is blind to bursts and caches;")
    print("jsq corrects imbalance as it forms; least_cost also sees compile")
    print("costs and merge opportunities; affinity minimizes cold starts at")
    print("the price of hot-replica tails. Per-replica detail: "
          "FleetMetrics.per_replica / .routed_counts.")

    # ---- heterogeneous + elastic: the committed spec, as-is and tweaked ----
    hetero = SystemSpec.load(HETERO_SPEC).replace(**{
        "workload.events": EVENTS})
    print(f"\n=== mixed v5e + v5e_half fleet ({HETERO_SPEC}) ===")
    print(f"{'cell':22s} {'p95 ms':>8s} {'goodput':>10s} {'replicas':>9s}")
    for name, overrides in (
        ("hetero round_robin", {"fleet.replicas": REPLICAS,
                                "fleet.autoscale": None,
                                "router.policy": "round_robin"}),
        ("hetero least_cost", {"fleet.replicas": REPLICAS,
                               "fleet.autoscale": None,
                               "router.policy": "least_cost"}),
        ("elastic least_cost", {}),  # the committed spec: grown from 1
    ):
        m = hetero.replace(**overrides).build().run_metrics()
        s = m.summary()
        repl = f"{m.initial_replicas}->{m.final_active}" if m.scale_events \
            else str(m.final_active)
        print(f"{name:22s} {s['p95_s']*1e3:8.3f} "
              f"{s['goodput_cost_per_s']:10.4g} {repl:>9s}")
    print("\nspeed-aware least_cost routes around the slow chips that blind")
    print("round_robin trips over; the elastic fleet grows on the backlog")
    print("signal, each new replica arriving with a stone-cold compile cache")
    print("(FleetMetrics.scale_events has the full timeline).")


if __name__ == "__main__":
    main()
