"""Discrete-event simulator over the real scheduling core.

NOT a model of the scheduler — the actual ``DynamicSpaceTimeScheduler``
(same queue, same batching policies, same admission control, same
straggler eviction) runs on a ``VirtualClock``, with a cost model pricing
each super-dispatch. Only the kernels are replaced: simulated workloads
carry a no-op executor, so a million-event policy sweep runs in seconds
on CPU with zero device work — and any policy conclusion transfers to the
live pump because it IS the live pump.

The event machinery lives in ``ReplicaPump``: one scheduler on one
virtual clock plus the ripeness-instant drain loop. The solo
``Simulator`` wraps exactly one pump; the fleet simulator
(``repro.sim.fleet``) wraps N of them behind a router and merges their
ripeness instants into one global timeline — same pump, same event
semantics, so solo and fleet results are directly comparable.

Event ordering: between consecutive trace arrivals the loop advances the
virtual clock to each bucket's next ripeness instant and pumps there, so
batching-window dispatches happen at their exact modeled time rather than
being quantized to arrival times. Arrivals are stamped with their TRACE
time even when the (busy) virtual clock has run ahead — queueing delay
under overload is measured honestly.

Determinism: trace generation is seeded numpy, the clock is virtual, the
cost model is pure arithmetic — same seed in, byte-identical metrics JSON
out. That contract is what lets CI assert on simulated SLO orderings.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Iterable, List, Optional, Sequence

from repro.config import ScheduleConfig
from repro.core.clock import VirtualClock
from repro.core.scheduler import DynamicSpaceTimeScheduler
from repro.sim.costmodel import RooflineCostModel
from repro.sim.metrics import MetricsAccumulator, SimMetrics
from repro.sim.traces import Arrival, Trace


def _noop_execute(batch: List) -> List[None]:
    return [None] * len(batch)


class SimWorkload:
    """Minimal object satisfying the scheduler's Workload protocol.

    Deliberately not the ``Workload`` dataclass: a ``__slots__`` class with
    a no-op executor keeps per-event cost low enough for million-event
    traces (the dataclass's default-factory fields roughly double intake
    time at that scale).

    ``est_s`` is the router's estimated solo dispatch seconds for this
    item (0.0 outside fleet runs) — the pump subtracts it back out of its
    backlog estimate on completion.
    """

    __slots__ = ("tenant_id", "bucket", "cost", "slo_s", "kind", "flops",
                 "bytes", "merge_family", "execute", "arrival_time",
                 "result", "completion_time", "est_s")

    def __init__(self, spec, cost: float):
        self.tenant_id = spec.tenant_id
        self.bucket = spec.bucket
        self.cost = cost
        self.slo_s = spec.slo_s
        self.kind = spec.kind
        self.flops = spec.flops
        self.bytes = spec.bytes
        self.merge_family = None  # ragged merge is a live-kernel concern
        self.execute = _noop_execute
        self.arrival_time = 0.0
        self.result = None
        self.completion_time = None
        self.est_s = 0.0


class ReplicaPump:
    """One replica of the real scheduler on its own virtual clock, plus
    the ripeness-instant drain machinery — the unit both the solo
    ``Simulator`` and the fleet simulator are built from."""

    # 1 simulated nanosecond — larger than any float rounding error at
    # realistic trace horizons, negligible against microsecond dispatches
    _RIPE_EPS = 1e-9

    def __init__(
        self,
        schedule: Optional[ScheduleConfig] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        start_s: float = 0.0,
        clock: Optional[VirtualClock] = None,
        replica_id: Optional[int] = None,
    ):
        self.replica_id = replica_id
        self.clock = clock if clock is not None else VirtualClock(start_s)
        self.cost_model = cost_model or RooflineCostModel()
        self.scheduler = DynamicSpaceTimeScheduler(
            schedule or ScheduleConfig(),
            clock=self.clock,
            cost_model=self.cost_model,
            replica_id=replica_id,
        )
        # metric sinks every completion is recorded into (solo: one; fleet:
        # the replica's own + the fleet-wide accumulator)
        self.accs: List[MetricsAccumulator] = []
        # fleet-only: hardware label for per-replica summaries (hetero
        # fleets), relative chip speed (weighted-affinity routing signal),
        # and an optional ROUTING-time pricing model (per-replica
        # calibrated table) — the true cost_model still drives the clock
        self.spec_name: Optional[str] = None
        self.speed_factor: float = 1.0
        self.route_model: Optional[Callable[[Sequence], float]] = None
        # router's running backlog estimate: Σ est_s of pending items
        self.pending_est_s = 0.0
        # fleet-only (set by FleetSimulator): completion instants of
        # dispatched items, so queue_depth(now) can count work that is
        # modeled as done on this replica's (ahead) clock but still in
        # flight at the fleet's current instant. Off in solo runs — a
        # million-event trace must not accumulate a million floats.
        self.track_inflight = False
        self._inflight: deque = deque()

    # ------------------------------------------------------------- intake
    def submit(self, w: SimWorkload, t_s: float) -> bool:
        """Advance to the arrival instant, admit, and pump immediately.

        The TRUE trace time is stamped even when this replica's (busy)
        clock has run ahead — queueing delay under overload stays honest.
        """
        self.clock.advance_to(t_s)
        admitted = self.scheduler.submit(w, now=t_s)
        if admitted:
            self.pending_est_s += w.est_s
        # pump even when admission rejected: advancing to t_s may have
        # ripened other buckets (drain_until only covers instants < t_s)
        self._absorb(self.scheduler.pump())
        return admitted

    # ---------------------------------------------------------- event loop
    def next_ripe_time(self) -> Optional[float]:
        """Earliest instant any bucket becomes dispatchable.

        For slack-aware policies the window shrinks as time passes, so
        ``oldest + window(now)`` is an upper bound on the true ripeness
        instant — pumping there is guaranteed to dispatch (the estimate
        errs at most by how much the window shrank in between), which
        keeps the drain loop strictly progressing.
        """
        sched = self.scheduler
        now = self.clock.now()
        queue, policy = sched.queue, sched.policy
        cap = sched.schedule.max_superkernel_size
        best = None
        for bucket, count in queue.buckets():
            if count >= cap:
                return now
            oldest = queue.oldest_arrival(bucket)
            pending = queue.peek(bucket) if policy.needs_pending else ()
            t = max(now, oldest + policy.window_s(pending, now))
            if best is None or t < best:
                best = t
        return best

    def pump_at(self, t_ripe: float) -> List:
        """Advance to a ripeness instant and pump; nudge one epsilon past
        it if float rounding left the window a ULP short of elapsed."""
        self.clock.advance_to(t_ripe)
        done = self.scheduler.pump()
        if not done:
            self.clock.advance_to(t_ripe + self._RIPE_EPS)
            done = self.scheduler.pump()
        self._absorb(done)
        return done

    def drain_until(self, t_limit: float) -> None:
        """Pump every bucket that ripens strictly before ``t_limit``."""
        while True:
            t_ripe = self.next_ripe_time()
            if t_ripe is None or t_ripe >= t_limit:
                return
            if not self.pump_at(t_ripe):
                return  # estimate failed to ripen anything; arrivals resume

    def drain_tail(self) -> None:
        """Drain at exact ripeness instants, then force-flush the rest."""
        sched = self.scheduler
        while len(sched.queue):
            t_ripe = self.next_ripe_time()
            if t_ripe is None or not self.pump_at(t_ripe):
                self._absorb(sched.flush())
                break

    def _absorb(self, done: List) -> None:
        track = self.track_inflight
        for w in done:
            self.pending_est_s -= w.est_s
            lat = w.completion_time - w.arrival_time
            for acc in self.accs:
                acc.add(w.tenant_id, lat, w.slo_s, w.cost, w.kind)
            if track:
                self._inflight.append(w.completion_time)
        if self.pending_est_s < 0.0:  # float dust from += / -= pairs
            self.pending_est_s = 0.0

    # ------------------------------------------------------ routing signals
    def queue_depth(self, now: Optional[float] = None) -> int:
        """Occupancy as a router sees it: items pending in the queue plus
        items whose modeled completion lies beyond the fleet's current
        instant (this replica's clock ran ahead; the work is still in
        flight in fleet time even though this replica already priced it).
        Without ``now`` (or in-flight tracking off) it is just the queue.
        """
        depth = len(self.scheduler.queue)
        if now is None or not self.track_inflight:
            return depth
        inflight = self._inflight
        while inflight and inflight[0] <= now:
            inflight.popleft()
        return depth + len(inflight)

    def backlog_s(self, now: float) -> float:
        """Estimated seconds until this replica would run dry: residual
        busy time (its clock ahead of global ``now``) plus the estimated
        cost of everything still queued."""
        return max(0.0, self.clock.now() - now) + self.pending_est_s

    def estimate_item_s(self, w) -> float:
        """Estimated seconds this item adds to THIS replica.

        If the item's bucket already has pending items here it rides the
        forming super-kernel — marginal roofline cost only, compile shared
        with the batch. Otherwise it opens a fresh dispatch: full solo
        cost, plus the compile term when this replica's cache is cold for
        the bucket (the warm-affinity signal).

        When a ``route_model`` is attached (fleet calibration: this
        replica's measured-cost table), routing prices through IT instead
        of the true model — the convergence loop that turns wrong priors
        into measured per-replica costs."""
        model = self.route_model if self.route_model is not None \
            else self.cost_model
        if self.scheduler.queue.head(w.bucket) is not None:
            item_s = getattr(model, "item_s", None)
            if item_s is not None:
                return item_s(w)
        estimate = getattr(model, "estimate", None)
        if estimate is not None:
            return estimate((w,))
        return model((w,))

    def freeze(self, acc: MetricsAccumulator,
               sim_duration_s: float) -> SimMetrics:
        """Freeze one accumulator against this replica's scheduler stats."""
        sched = self.scheduler
        return acc.freeze(
            sim_duration_s=sim_duration_s,
            busy_time_s=sched.stats.busy_time_s,
            dispatches=sched.stats.dispatches,
            rejected=sched.stats.rejected,
            evicted_tenants=len(sched.evicted),
        )


class Simulator:
    """Drives the real scheduler over a trace on a virtual timeline."""

    def __init__(
        self,
        schedule: Optional[ScheduleConfig] = None,
        cost_model: Optional[Callable[[Sequence], float]] = None,
        start_s: float = 0.0,
    ):
        self.pump = ReplicaPump(schedule=schedule, cost_model=cost_model,
                                start_s=start_s)
        self.clock = self.pump.clock
        self.scheduler = self.pump.scheduler

    def run(self, trace: Trace | Iterable[Arrival]) -> SimMetrics:
        pump = self.pump
        acc = MetricsAccumulator()
        pump.accs = [acc]
        submit, drain_until = pump.submit, pump.drain_until
        t_start = pump.clock.now()

        for t_s, spec, cost in trace:
            drain_until(t_s)
            submit(SimWorkload(spec, cost), t_s)
        pump.drain_tail()

        return pump.freeze(acc, sim_duration_s=pump.clock.now() - t_start)


def simulate(
    trace: Trace | Iterable[Arrival],
    schedule: Optional[ScheduleConfig] = None,
    cost_model: Optional[Callable[[Sequence], float]] = None,
) -> SimMetrics:
    """One-shot convenience wrapper: fresh simulator, one trace, metrics."""
    return Simulator(schedule=schedule, cost_model=cost_model).run(trace)
